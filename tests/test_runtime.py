"""Discrete-event runtime tests: channels, engine semantics, paper claims."""
import numpy as np

from repro.core.modes import AsyncMode
from repro.runtime.channels import Duct
from repro.runtime.faults import FaultModel, Jitter, faulty_node
from repro.runtime.simulator import SimConfig, Simulator
from repro.apps.graphcolor import GraphColorApp, GraphColorConfig


# ---------------------------------------------------------------------------
# Ducts
# ---------------------------------------------------------------------------
def test_duct_drop_on_full_buffer():
    d = Duct(capacity=2, latency_fn=lambda now: 0.001)
    assert d.try_send("a", 0.0, 0)
    assert d.try_send("b", 0.0, 0)
    assert not d.try_send("c", 0.0, 0)  # buffer full -> best-effort drop
    assert d.inlet.attempted_send_count == 3
    assert d.inlet.successful_send_count == 2
    # drops are counted at the drop site, never derived at report time
    assert d.inlet.dropped_send_count == 1


def test_drop_counter_symmetry():
    """attempted == successful + dropped holds at every point in time."""
    d = Duct(capacity=1, latency_fn=lambda now: 0.001)
    for k in range(5):
        d.try_send(k, 0.0, 0)
        i = d.inlet
        assert i.attempted_send_count == (i.successful_send_count
                                          + i.dropped_send_count)
    assert d.inlet.dropped_send_count == 4


def test_duct_latency_and_bulk_drain():
    d = Duct(capacity=10, latency_fn=lambda now: 0.5)
    d.try_send("a", 0.0, 0)
    d.try_send("b", 0.1, 0)
    assert d.pull(0.4) == []            # nothing available yet
    msgs = d.pull(0.55)                 # only "a" has arrived
    assert [m.payload for m in msgs] == ["a"]
    msgs = d.pull(1.0)                  # bulk drain picks up "b"
    assert [m.payload for m in msgs] == ["b"]
    assert d.outlet.pull_attempt_count == 3
    assert d.outlet.laden_pull_count == 2
    assert d.outlet.message_count == 2


def test_jitter_deterministic_and_unbiased():
    j = Jitter(sigma=0.2, seed=42)
    a = [j.factor(3, k) for k in range(2000)]
    b = [j.factor(3, k) for k in range(2000)]
    assert a == b
    assert abs(np.mean(a) - 1.0) < 0.05  # lognormal with mean-one correction


# ---------------------------------------------------------------------------
# Engine semantics
# ---------------------------------------------------------------------------
def _run(n, mode, duration=0.02, **kw):
    app = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=64))
    cfg = SimConfig(mode=mode, duration=duration, base_latency=100e-6, **kw)
    return Simulator(app, cfg).run()


def test_mode0_updates_lockstep():
    res = _run(4, AsyncMode.BARRIER_EVERY_STEP)
    assert max(res.updates) - min(res.updates) <= 1  # barrier every step


def test_best_effort_beats_barrier_rate():
    r0 = _run(16, AsyncMode.BARRIER_EVERY_STEP)
    r3 = _run(16, AsyncMode.BEST_EFFORT)
    assert r3.update_rate_per_cpu > 2.0 * r0.update_rate_per_cpu  # claim C1


def test_best_effort_quality_beats_barrier_and_no_comm():
    r0 = _run(16, AsyncMode.BARRIER_EVERY_STEP, duration=0.05)
    r3 = _run(16, AsyncMode.BEST_EFFORT, duration=0.05)
    r4 = _run(16, AsyncMode.NO_COMM, duration=0.05)
    assert r3.quality < r0.quality   # claim C2: more progress in fixed time
    assert r3.quality < r4.quality   # communication matters


def test_no_comm_mode_sends_nothing():
    res = _run(4, AsyncMode.NO_COMM)
    assert res.sent == 0


def test_drops_happen_with_tiny_buffer_and_slow_consumer():
    app = GraphColorApp(GraphColorConfig(n_processes=2, nodes_per_process=16))
    faults = FaultModel(compute_slowdown={1: 20.0})
    cfg = SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.05,
                    buffer_capacity=2, base_latency=20e-6)
    sim = Simulator(app, cfg, faults)
    res = sim.run()
    assert res.dropped > 0  # fast producer overflows the slow consumer's duct
    # SimResult.dropped comes from the explicit per-process drop counters,
    # and they agree with the duct-level inlet counters
    assert res.dropped == sum(sim._c_drop)
    assert res.dropped == sum(d.inlet.dropped_send_count
                              for d in sim.ducts.values())
    assert res.sent == res.dropped + sum(sim._c_ok)


def test_qos_windows_produced():
    res = _run(4, AsyncMode.BEST_EFFORT, duration=1.0,
               snapshot_warmup=0.2, snapshot_interval=0.2)
    assert len(res.qos) >= 4 * 3  # >=3 windows per process
    for rep in res.qos:
        assert rep.simstep_period > 0
        assert 0 <= rep.delivery_failure_rate <= 1


def test_faulty_node_degrades_itself_not_the_median():
    """Claim C4: extreme degradation on one node's clique, stable medians."""
    n = 16
    app = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=64))
    topo = app.topology()
    faults = faulty_node(5, topo[5], compute_factor=30.0, link_factor=30.0)
    cfg = SimConfig(mode=AsyncMode.BEST_EFFORT, duration=0.5,
                    snapshot_warmup=0.1, snapshot_interval=0.1,
                    base_latency=100e-6)
    res_f = Simulator(app, cfg, faults).run()
    app2 = GraphColorApp(GraphColorConfig(n_processes=n, nodes_per_process=64))
    res_ok = Simulator(app2, cfg).run()

    per_f = [np.median([q.simstep_period for q in res_f.qos_by_process[p]])
             for p in range(n) if res_f.qos_by_process[p]]
    med_f = float(np.median(per_f))
    per_ok = [np.median([q.simstep_period for q in res_ok.qos_by_process[p]])
              for p in range(n) if res_ok.qos_by_process[p]]
    med_ok = float(np.median(per_ok))
    # faulty node is drastically slower than the population median
    faulty_period = np.median([q.simstep_period for q in res_f.qos_by_process[5]])
    assert faulty_period > 5 * med_f
    # but the global median barely moves
    assert med_f < 1.5 * med_ok
    # and total progress is not dragged down to the faulty node's rate
    healthy = [u for p, u in enumerate(res_f.updates) if p != 5]
    assert min(healthy) > 3 * res_f.updates[5]
